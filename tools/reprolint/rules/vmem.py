"""Rule ``vmem`` — static Pallas VMEM budget checking (docs/DESIGN.md §16).

For every ``pl.pallas_call`` in a kernel file, statically bound the VMEM
footprint:

    (sum of in/out BlockSpec block bytes) * double_buffer
        + sum of scratch_shapes bytes          <=  budget

Block shapes are expressions over tile-size locals (``bq``, ``bn``, ...), so
the rule runs a small **upper-bound abstract interpreter** over the enclosing
function body:

  * parameters seed from their declared defaults (``bq: int = 128``) or from
    ``x or DEFAULT`` re-binding; a caller overriding tiles upward is outside
    static scope (the runtime asserts / trace audit own that);
  * ``min(a, b)`` keeps the smallest known bound (unknown operands are
    ignored — ``min`` can only shrink); ``max``/``+``/``*`` need all
    operands bounded; ``a // b`` with unknown ``b`` bounds to ``a``
    (divisors are >= 1 here); ``common.round_up(x, m)`` bounds to
    ``x + m - 1``; ``common.next_pow2(x)`` to ``next_pow2(x)``;
  * ``if``/``else`` join per-name bounds with ``max`` (either branch may
    run);
  * names that stay unknown (runtime static args like ``depth``) fall back
    to ``config.vmem_assumed_bounds``; a block dimension that cannot be
    bounded at all is itself a finding.

Dtypes resolve from ``jnp.<dtype>`` spellings; unresolved dtypes charge the
conservative ``vmem_default_itemsize`` (4 bytes).  BlockSpecs constructed in
helper functions (no ``pallas_call`` of their own) are charged to each
caller at the helper's largest block, with the helper's parameters bound to
the caller's argument bounds.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Tuple

from tools.reprolint.framework import FileContext, Finding, Rule, call_name

Env = Dict[str, Optional[int]]

_DTYPE_BYTES = {
    "float64": 8, "int64": 8, "uint64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool_": 1, "bool": 1,
}
_COMMON_CONSTS = {
    "LANE": 128, "SUBLANE_F32": 8, "SUBLANE_BF16": 16, "SUBLANE_INT8": 32,
}


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


class _Evaluator:
    """Upper-bound abstract interpretation of one function body."""

    def __init__(self, fn: ast.FunctionDef, assumed: Dict[str, int]):
        self.assumed = assumed
        self.env: Env = {}
        args = fn.args
        pos = args.posonlyargs + args.args
        defaults: List[Optional[ast.expr]] = (
            [None] * (len(pos) - len(args.defaults)) + list(args.defaults)
        )
        for a, d in zip(pos, defaults):
            self.env[a.arg] = self._const(d) if d is not None else None
        for a, d in zip(args.kwonlyargs, args.kw_defaults):
            self.env[a.arg] = self._const(d) if d is not None else None
        self._run_body(fn.body)

    @staticmethod
    def _const(node: Optional[ast.expr]) -> Optional[int]:
        if isinstance(node, ast.Constant) and isinstance(node.value, int) \
                and not isinstance(node.value, bool):
            return node.value
        return None

    # -- statements ---------------------------------------------------------

    def _run_body(self, body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, ast.Assign):
                self._assign(stmt.targets, stmt.value)
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                self._assign([stmt.target], stmt.value)
            elif isinstance(stmt, ast.AugAssign):
                if isinstance(stmt.target, ast.Name):
                    self.env[stmt.target.id] = None
            elif isinstance(stmt, ast.If):
                before = dict(self.env)
                self._run_body(stmt.body)
                after_if = self.env
                self.env = dict(before)
                self._run_body(stmt.orelse)
                joined: Env = {}
                for k in set(after_if) | set(self.env):
                    a, b = after_if.get(k), self.env.get(k)
                    joined[k] = max(a, b) if (a is not None and b is not None) \
                        else None
                self.env = joined
            # for/while/with/try bodies never bind tile sizes in this repo;
            # anything they do bind stays unknown (conservative).

    def _assign(self, targets: Sequence[ast.expr], value: ast.expr) -> None:
        for tgt in targets:
            if isinstance(tgt, ast.Tuple) and isinstance(value, ast.Tuple) \
                    and len(tgt.elts) == len(value.elts):
                for t, v in zip(tgt.elts, value.elts):
                    if isinstance(t, ast.Name):
                        self.env[t.id] = self.bound(v)
            elif isinstance(tgt, ast.Name):
                self.env[tgt.id] = self.bound(value)
            elif isinstance(tgt, ast.Tuple):
                for t in tgt.elts:
                    if isinstance(t, ast.Name):
                        self.env[t.id] = None

    # -- expressions --------------------------------------------------------

    def bound(self, node: Optional[ast.expr]) -> Optional[int]:
        """Upper bound for an int expression; None = unbounded/unknown."""
        if node is None:
            return None
        if isinstance(node, ast.Constant):
            return self._const(node)
        if isinstance(node, ast.Name):
            v = self.env.get(node.id)
            if v is not None:
                return v
            return self.assumed.get(node.id)
        if isinstance(node, ast.Attribute):
            if node.attr in _COMMON_CONSTS:
                return _COMMON_CONSTS[node.attr]
            return self.assumed.get(node.attr)
        if isinstance(node, ast.BoolOp) and isinstance(node.op, ast.Or):
            # ``x or DEFAULT``: either operand may win; bound = max(known).
            known = [b for b in map(self.bound, node.values) if b is not None]
            return max(known) if known else None
        if isinstance(node, ast.BinOp):
            left, right = self.bound(node.left), self.bound(node.right)
            if isinstance(node.op, ast.FloorDiv):
                if left is None:
                    return None
                if right is None or right <= 0:
                    return left          # divisor >= 1 by construction
                if self._const(node.right) is not None:
                    return left // right  # exact divisor: monotone
                return left
            if left is None or right is None:
                return None
            if isinstance(node.op, ast.Add):
                return left + right
            if isinstance(node.op, ast.Sub):
                return left  # b >= 0 everywhere relevant
            if isinstance(node.op, ast.Mult):
                return left * right
            if isinstance(node.op, ast.LShift):
                return left << right
            return None
        if isinstance(node, ast.Call):
            return self._call_bound(node)
        return None

    def _call_bound(self, node: ast.Call) -> Optional[int]:
        name = call_name(node) or ""
        short = name.rsplit(".", 1)[-1]
        if short == "min":
            known = [b for b in map(self.bound, node.args) if b is not None]
            return min(known) if known else None
        if short == "max":
            bounds = [self.bound(a) for a in node.args]
            if any(b is None for b in bounds) or not bounds:
                return None
            return max(b for b in bounds if b is not None)
        if short == "round_up" and len(node.args) == 2:
            x, m = self.bound(node.args[0]), self.bound(node.args[1])
            return None if x is None or m is None else x + m - 1
        if short == "next_pow2" and len(node.args) == 1:
            x = self.bound(node.args[0])
            return None if x is None else _next_pow2(x)
        return None


def _itemsize(node: Optional[ast.expr], default: int) -> int:
    if node is None:
        return default
    if isinstance(node, ast.Attribute):
        return _DTYPE_BYTES.get(node.attr, default)
    if isinstance(node, ast.Name):
        return _DTYPE_BYTES.get(node.id, default)
    return default


def _shape_dims(node: ast.expr) -> Optional[List[ast.expr]]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return list(node.elts)
    return None


class _SpecCost:
    def __init__(self, line: int, kind: str, dims: List[str], bytes_: Optional[int],
                 unknown: Optional[str] = None):
        self.line = line
        self.kind = kind          # "block" | "scratch"
        self.dims = dims
        self.bytes = bytes_
        self.unknown = unknown    # name of the dim that could not be bounded


def _collect_specs(
    fn: ast.FunctionDef, ev: _Evaluator, default_itemsize: int,
) -> Tuple[List[_SpecCost], List[str]]:
    """All BlockSpec / MemorySpace.VMEM costs lexically inside ``fn``, plus
    the names of helper functions it calls (for helper attribution)."""
    specs: List[_SpecCost] = []
    callees: List[str] = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node) or ""
        short = name.rsplit(".", 1)[-1]
        if short == "BlockSpec" and node.args:
            dims = _shape_dims(node.args[0])
            if dims is None:
                specs.append(_SpecCost(node.lineno, "block", [], None,
                                       unknown="<non-literal shape>"))
                continue
            total, bad, names = 1, None, []
            for d in dims:
                b = ev.bound(d)
                names.append(ast.unparse(d))
                if b is None:
                    bad = ast.unparse(d)
                    break
                total *= b
            if bad is not None:
                specs.append(_SpecCost(node.lineno, "block", names, None,
                                       unknown=bad))
            else:
                specs.append(_SpecCost(
                    node.lineno, "block", names, total * default_itemsize
                ))
        elif short == "VMEM" and len(node.args) >= 1:
            dims = _shape_dims(node.args[0])
            if dims is None:
                continue
            isz = _itemsize(node.args[1] if len(node.args) > 1 else None,
                            default_itemsize)
            total, bad, names = 1, None, []
            for d in dims:
                b = ev.bound(d)
                names.append(ast.unparse(d))
                if b is None:
                    bad = ast.unparse(d)
                    break
                total *= b
            if bad is not None:
                specs.append(_SpecCost(node.lineno, "scratch", names, None,
                                       unknown=bad))
            else:
                specs.append(_SpecCost(node.lineno, "scratch", names,
                                       total * isz))
        elif isinstance(node.func, ast.Name):
            callees.append(node.func.id)
    return specs, callees


class VmemBudgetRule(Rule):
    name = "vmem"

    def check(self, ctx: FileContext) -> List[Finding]:
        if not ctx.matches(ctx.config.kernel_globs):
            return []
        out: List[Finding] = []
        assumed = dict(ctx.config.vmem_assumed_bounds)
        default_isz = ctx.config.vmem_default_itemsize

        module_fns: Dict[str, ast.FunctionDef] = {
            n.name: n for n in ctx.tree.body if isinstance(n, ast.FunctionDef)
        }

        def has_pallas_call(fn: ast.FunctionDef) -> bool:
            return any(
                isinstance(n, ast.Call)
                and (call_name(n) or "").endswith("pallas_call")
                for n in ast.walk(fn)
            )

        def helper_cost(helper: ast.FunctionDef, caller_ev: _Evaluator,
                        call: ast.Call) -> Optional[int]:
            """Largest block the helper can emit, with its params bound to
            the caller's argument bounds (conservative: a helper returns one
            of its specs per call path)."""
            hev = _Evaluator(helper, assumed)
            params = [a.arg for a in helper.args.posonlyargs + helper.args.args]
            for p, arg in zip(params, call.args):
                b = caller_ev.bound(arg)
                if b is not None:
                    hev.env[p] = b
            hev._run_body(helper.body)  # re-run with caller bounds
            specs, _ = _collect_specs(helper, hev, default_isz)
            block_bytes = [s.bytes for s in specs
                           if s.kind == "block" and s.bytes is not None]
            return max(block_bytes) if block_bytes else None

        for fn in module_fns.values():
            if not has_pallas_call(fn):
                continue
            budget = ctx.config.vmem_budgets.get(
                fn.name, ctx.config.vmem_budget_bytes
            )
            ev = _Evaluator(fn, assumed)
            specs, callees = _collect_specs(fn, ev, default_isz)

            block_bytes = 0
            scratch_bytes = 0
            for s in specs:
                if s.bytes is None:
                    out.append(self.finding(
                        ctx, s.line,
                        f"{fn.name}: cannot bound {s.kind} dimension "
                        f"{s.unknown!r} — add it to vmem_assumed_bounds in "
                        "reprolint.json or make the tile size a literal",
                    ))
                elif s.kind == "block":
                    block_bytes += s.bytes
                else:
                    scratch_bytes += s.bytes

            for callee_name in callees:
                helper = module_fns.get(callee_name)
                if helper is None or helper is fn or has_pallas_call(helper):
                    continue
                hc = helper_cost(helper, ev, next(
                    n for n in ast.walk(fn)
                    if isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Name)
                    and n.func.id == callee_name
                ))
                if hc is not None:
                    block_bytes += hc

            total = (
                block_bytes * ctx.config.vmem_double_buffer + scratch_bytes
            )
            if total > budget:
                out.append(self.finding(
                    ctx, fn.lineno,
                    f"{fn.name}: estimated VMEM {total / 2**20:.2f} MiB "
                    f"(blocks {block_bytes / 2**20:.2f} MiB x"
                    f"{ctx.config.vmem_double_buffer} double-buffer + "
                    f"scratch {scratch_bytes / 2**20:.2f} MiB) exceeds the "
                    f"{budget / 2**20:.2f} MiB budget — shrink the tile "
                    "sizes or raise vmem_budgets[\"" + fn.name + "\"]",
                ))
        return out
