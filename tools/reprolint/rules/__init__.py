"""Rule registry: ``all_rules()`` is what the CLI and CI run."""
from __future__ import annotations

from typing import List

from tools.reprolint.framework import Rule
from tools.reprolint.rules.hostsync import HostSyncRule
from tools.reprolint.rules.lockdiscipline import LockDisciplineRule
from tools.reprolint.rules.retrace import RetraceRule
from tools.reprolint.rules.vmem import VmemBudgetRule


def all_rules() -> List[Rule]:
    return [RetraceRule(), VmemBudgetRule(), HostSyncRule(), LockDisciplineRule()]
