"""Dynamic counterpart to the static ``retrace`` rule.

The static rule flags the *shapes* of recompile-churn bugs; this module
counts the *events*.  jax emits a monitoring event per backend compile
(``.../backend_compile_duration`` — verified to fire exactly once per
executable built, and not at all on cache hits, under the pinned jax), so a
test can assert a hard ceiling on compiles across a workload::

    with assert_max_traces(0):
        for _ in range(10):
            serve_one_batch()   # steady state must reuse executables

or via the pytest fixture::

    def test_steady_state(trace_audit):
        warmup()
        trace_audit.reset()
        run_cycles(10)
        trace_audit.assert_max(1)

This replaces the hand-rolled ``cache.compiles``-counter assertions that
grew in test_packed.py: those only see compiles routed through
``ExecutableCache``, while the monitoring listener sees every jit retrace
that reaches the backend, including ones that bypass the cache entirely.

The listener registers once per process (jax.monitoring has no
per-listener deregistration; ``clear_event_listeners`` would clobber other
users) and only ever increments counters, so it is safe to leave in place.
"""
from __future__ import annotations

import threading
from typing import Optional

_BACKEND_COMPILE_SUFFIX = "backend_compile_duration"
_TRACE_SUBSTR = "trace_duration"

_counts = {"compiles": 0, "traces": 0}
_registered = False
_reg_lock = threading.Lock()


def _on_event(event: str, duration: float, **kwargs) -> None:
    if event.endswith(_BACKEND_COMPILE_SUFFIX):
        _counts["compiles"] += 1
    elif _TRACE_SUBSTR in event:
        _counts["traces"] += 1


def ensure_registered() -> None:
    """Install the monitoring listener (idempotent, process-wide)."""
    global _registered
    with _reg_lock:
        if _registered:
            return
        from jax import monitoring

        monitoring.register_event_duration_secs_listener(_on_event)
        _registered = True


def compile_count() -> int:
    """Backend compiles observed since the listener was installed."""
    ensure_registered()
    return _counts["compiles"]


def trace_count() -> int:
    """Jaxpr traces observed (informational: a trace that hits the jit
    cache never reaches the backend and is cheap; compiles are the cost)."""
    ensure_registered()
    return _counts["traces"]


class assert_max_traces:
    """Context manager: at most ``n`` backend compiles inside the block.

    >>> with assert_max_traces(1, "bucket growth compiles once"):
    ...     refresh_and_search()
    """

    def __init__(self, n: int, message: str = ""):
        self.n = n
        self.message = message
        self.compiles: Optional[int] = None  # filled on exit

    def __enter__(self) -> "assert_max_traces":
        ensure_registered()
        self._start = _counts["compiles"]
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.compiles = _counts["compiles"] - self._start
        if exc_type is None and self.compiles > self.n:
            suffix = f" ({self.message})" if self.message else ""
            raise AssertionError(
                f"observed {self.compiles} backend compile(s), "
                f"expected at most {self.n}{suffix} — something in the "
                "block retraces per call (see tools/reprolint rule "
                "'retrace' for the usual causes)"
            )
        return False


class TraceAudit:
    """Fixture handle: windowed compile counting with reset."""

    def __init__(self):
        ensure_registered()
        self.reset()

    def reset(self) -> None:
        self._start = _counts["compiles"]

    @property
    def compiles(self) -> int:
        return _counts["compiles"] - self._start

    def assert_max(self, n: int, message: str = "") -> None:
        got = self.compiles
        if got > n:
            suffix = f" ({message})" if message else ""
            raise AssertionError(
                f"observed {got} backend compile(s) since reset, "
                f"expected at most {n}{suffix}"
            )


try:  # pytest is present in dev/CI; the module stays importable without it
    import pytest
except ImportError:  # pragma: no cover
    pytest = None

if pytest is not None:
    @pytest.fixture
    def trace_audit() -> TraceAudit:
        """Counts backend compiles; ``reset()`` after warmup, then
        ``assert_max(n)`` (or read ``.compiles``)."""
        return TraceAudit()
