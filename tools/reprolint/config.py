"""reprolint configuration.

Defaults are tailored to this repo and can be overridden by a
``reprolint.json`` file at the analysis root (the repo root in CI).  The
config answers three questions the analyzers cannot answer from the AST
alone:

  * which files are **hot paths** (host-sync lint scope);
  * the per-kernel **VMEM budgets** and the assumed upper bounds for tile
    dimensions the abstract evaluator cannot derive statically (runtime
    static args like ``depth``);
  * the **lock-discipline** contract of the async serving class (which
    methods run on the worker thread, which attribute guards them).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Tuple

MIB = 1024 * 1024


@dataclasses.dataclass
class LockContract:
    """One class's lock-discipline contract (rule ``lockdiscipline``)."""

    path_glob: str                 # file the class lives in
    class_name: str
    lock_attr: str = "_lock"
    # Methods that run on the worker thread (call-graph roots for the
    # "mutated on the worker thread" attribute set).
    worker_entries: Tuple[str, ...] = ()
    # Methods that run before/outside concurrency (construction, worker
    # lifecycle) — their mutations are exempt and they count as lock-held
    # for call-graph propagation.
    exempt_methods: Tuple[str, ...] = ("__init__",)
    # Attributes that are internally synchronized (queue.Queue,
    # threading.Event) — mutation without the service lock is fine.
    threadsafe_attrs: Tuple[str, ...] = ()
    # Attributes guarded by contract even if no worker-thread mutation is
    # visible statically (e.g. counters bumped from many caller threads).
    extra_guarded: Tuple[str, ...] = ()


@dataclasses.dataclass
class Config:
    # ---- hostsync ---------------------------------------------------------
    # Files whose function bodies are hot paths: no host syncs unwaived.
    hot_path_globs: Tuple[str, ...] = (
        "src/repro/serve/*.py",
        "src/repro/core/packed.py",
    )
    # Files where only ``__call__`` methods of matcher-layer classes
    # (class names matching ``*Matcher`` / ``FilterMask``) are hot.
    matcher_call_globs: Tuple[str, ...] = ("src/repro/core/pipeline.py",)
    matcher_class_patterns: Tuple[str, ...] = ("*Matcher", "FilterMask")

    # ---- vmem -------------------------------------------------------------
    # Only these files are kernel files (BlockSpec budget scope).
    kernel_globs: Tuple[str, ...] = ("src/repro/kernels/*/kernel.py",)
    vmem_budget_bytes: int = 16 * MIB
    # Per-kernel-function overrides, e.g. {"flash_attention": 8 * MIB}.
    vmem_budgets: Dict[str, int] = dataclasses.field(default_factory=dict)
    # Upper bounds assumed for dimensions the evaluator cannot derive (they
    # are runtime static args, not literals).  A kernel whose blocks scale
    # with an unlisted unknown dimension is itself a finding.
    vmem_assumed_bounds: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {
            # running top-k width: depth <= 2048 everywhere in this repo
            # (serving depth is 100; benches go to 1024); dpad is depth
            # rounded to the next pow2 >= LANE.
            "depth": 2048,
            "dpad": 2048,
            # head / reduced dims: flash attention d_h <= 256 on every
            # assigned arch; kd reductions are <= 8 dims, padded to LANE.
            "d": 256,
            "dim": 512,
        }
    )
    # Bytes per element when an operand/scratch dtype cannot be resolved
    # statically (conservative: f32/int32).
    vmem_default_itemsize: int = 4
    # Grid-streamed operands are double-buffered by the Pallas TPU
    # pipeline; scratch is single-buffered.
    vmem_double_buffer: int = 2

    # ---- retrace ----------------------------------------------------------
    # Enclosing functions whose jit-closure construction is the blessed
    # build-once pattern (stage builders, bind-time closures): a jit created
    # there is built per snapshot/bind, not per call.
    retrace_builder_patterns: Tuple[str, ...] = (
        "make_*", "build*", "_bind", "*_builder", "*_fn",
    )

    # ---- lockdiscipline ---------------------------------------------------
    lock_contracts: Tuple[LockContract, ...] = (
        LockContract(
            path_glob="src/repro/serve/ann_service.py",
            class_name="AnnService",
            lock_attr="_lock",
            worker_entries=("_batch_loop",),
            exempt_methods=("__init__", "start_async", "stop_async"),
            threadsafe_attrs=("_queue", "_stop", "_worker"),
            # rejected is bumped from arbitrary caller threads on admission
            # backpressure — guarded by contract even though the worker
            # never touches it.
            extra_guarded=("rejected",),
        ),
    )


def _coerce(field_val: Any, raw: Any) -> Any:
    if isinstance(field_val, tuple) and raw is not None:
        if field_val and isinstance(field_val[0], LockContract):
            return tuple(
                LockContract(**{
                    k: tuple(v) if isinstance(v, list) else v
                    for k, v in item.items()
                })
                for item in raw
            )
        return tuple(raw)
    return raw


def load(root: str = ".", path: Optional[str] = None) -> Config:
    """Config from ``<root>/reprolint.json`` (or an explicit path) merged
    over the in-tree defaults; missing file means pure defaults."""
    cfg = Config()
    cfg_path = path or os.path.join(root, "reprolint.json")
    if not os.path.exists(cfg_path):
        return cfg
    with open(cfg_path) as f:
        raw = json.load(f)
    for fld in dataclasses.fields(Config):
        if fld.name in raw:
            setattr(cfg, fld.name, _coerce(getattr(cfg, fld.name), raw[fld.name]))
    return cfg


def config_schema() -> List[str]:
    """Field names accepted in reprolint.json (for --help and docs)."""
    return [f.name for f in dataclasses.fields(Config)]
