"""reprolint — JAX/Pallas static analysis for this repo (docs/DESIGN.md §16).

Four analyzers over one shared AST visitor framework:

  * ``retrace``        — jit/retrace hygiene (recompile-churn class)
  * ``vmem``           — Pallas BlockSpec/scratch VMEM budget checker
  * ``hostsync``       — host-synchronization lint on designated hot paths
  * ``lockdiscipline`` — worker-thread attribute mutation under the lock

Run ``python -m tools.reprolint src/`` from the repo root; exit code 0 means
zero unwaived findings.  Inline waivers: ``# reprolint: disable=<rule>`` on
the offending line (or on a ``def`` line to waive that whole function) with a
justification comment.  The dynamic counterpart — a pytest trace-audit
fixture — lives in :mod:`tools.reprolint.trace_audit`.
"""
from tools.reprolint.framework import Finding, run_files  # noqa: F401

__all__ = ["Finding", "run_files"]
