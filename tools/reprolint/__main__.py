"""CLI: ``python -m tools.reprolint [paths...]``.

Exit status 0 when every finding is waived (or there are none); 1 when any
unwaived finding remains; 2 on usage errors.  CI runs::

    python -m tools.reprolint src/

``--show-waived`` also prints waived findings (with a ``(waived)`` tag) so
stale waivers stay visible in review.
"""
from __future__ import annotations

import argparse
import sys
from typing import List

from tools.reprolint import config as config_mod
from tools.reprolint.framework import run_files
from tools.reprolint.rules import all_rules


def main(argv: List[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="reprolint",
        description="repo-specific JAX/Pallas static analysis "
        "(retrace, vmem, hostsync, lockdiscipline)",
    )
    parser.add_argument("paths", nargs="*", default=["src/"],
                        help="files or directories to check (default: src/)")
    parser.add_argument("--config", default=None,
                        help="explicit reprolint.json path "
                        "(default: ./reprolint.json if present)")
    parser.add_argument("--show-waived", action="store_true",
                        help="also print waived findings")
    parser.add_argument("--rules", default=None,
                        help="comma-separated subset of rules to run")
    parser.add_argument("--list-rules", action="store_true")
    args = parser.parse_args(argv)

    rules = all_rules()
    if args.list_rules:
        for r in rules:
            print(r.name)
        print("config keys:", ", ".join(config_mod.config_schema()))
        return 0
    if args.rules:
        wanted = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = wanted - {r.name for r in rules}
        if unknown:
            print(f"unknown rule(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2
        rules = [r for r in rules if r.name in wanted]

    cfg = config_mod.load(".", args.config)
    findings = run_files(args.paths or ["src/"], rules, cfg)

    unwaived = [f for f in findings if not f.waived]
    waived = [f for f in findings if f.waived]
    for f in unwaived:
        print(f.format())
    if args.show_waived:
        for f in waived:
            print(f.format())

    print(
        f"reprolint: {len(unwaived)} finding(s), {len(waived)} waived",
        file=sys.stderr,
    )
    return 1 if unwaived else 0


if __name__ == "__main__":
    sys.exit(main())
