"""Shared visitor framework for the reprolint analyzers.

One parse per file; every rule receives the same :class:`FileContext` (path,
source lines, AST with parent links, waiver table) and returns
:class:`Finding` objects.  Waivers:

  * ``# reprolint: disable=<rule>[,<rule>...]`` on a line waives findings of
    those rules on that line;
  * the same comment on (or immediately above) a ``def``/``class`` line
    waives the whole lexical scope of that definition;
  * ``disable=all`` waives every rule.

Waived findings are still collected (reported under ``--show-waived``) so a
waiver can never silently hide a rule that stopped matching.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from tools.reprolint.config import Config

_WAIVER_RE = re.compile(r"#\s*reprolint:\s*disable=([A-Za-z0-9_,\- ]+)")


@dataclasses.dataclass
class Finding:
    rule: str
    path: str
    line: int
    message: str
    waived: bool = False

    def format(self) -> str:
        tag = " (waived)" if self.waived else ""
        return f"{self.path}:{self.line}: [{self.rule}]{tag} {self.message}"


class FileContext:
    """Everything a rule needs about one file, parsed once."""

    def __init__(self, path: str, source: str, config: Config):
        self.path = path.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.config = config
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                child._reprolint_parent = parent  # type: ignore[attr-defined]
        self._line_waivers = self._parse_line_waivers()
        self._scope_waivers = self._parse_scope_waivers()

    # -- waiver bookkeeping -------------------------------------------------

    def _parse_line_waivers(self) -> Dict[int, Set[str]]:
        out: Dict[int, Set[str]] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _WAIVER_RE.search(text)
            if m:
                # Keep the first word of each comma part: trailing prose
                # ("disable=hostsync  caller-side input") stays commentary.
                rules = {
                    r.strip().split()[0]
                    for r in m.group(1).split(",") if r.strip()
                }
                out[i] = rules
        return out

    def _parse_scope_waivers(self) -> List[Tuple[int, int, Set[str]]]:
        """(start, end, rules) ranges for waivers sitting on/above a def."""
        scopes: List[Tuple[int, int, Set[str]]] = []
        for node in ast.walk(self.tree):
            if not isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ):
                continue
            start = node.lineno
            header_lines = [start]
            if start > 1:
                header_lines.append(start - 1)  # comment-above style
            rules: Set[str] = set()
            for ln in header_lines:
                rules |= self._line_waivers.get(ln, set())
            if rules:
                end = max(
                    getattr(node, "end_lineno", start) or start, start
                )
                scopes.append((start, end, rules))
        return scopes

    def is_waived(self, rule: str, line: int) -> bool:
        for_line = self._line_waivers.get(line, set())
        if rule in for_line or "all" in for_line:
            return True
        for start, end, rules in self._scope_waivers:
            if start <= line <= end and (rule in rules or "all" in rules):
                return True
        return False

    # -- helpers rules share ------------------------------------------------

    def matches(self, globs: Sequence[str]) -> bool:
        return any(fnmatch.fnmatch(self.path, g) for g in globs)

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return getattr(node, "_reprolint_parent", None)

    def enclosing_function(
        self, node: ast.AST
    ) -> Optional[ast.FunctionDef]:
        cur = self.parent(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur  # type: ignore[return-value]
            cur = self.parent(cur)
        return None

    def enclosing_class(self, node: ast.AST) -> Optional[ast.ClassDef]:
        cur = self.parent(node)
        while cur is not None:
            if isinstance(cur, ast.ClassDef):
                return cur
            cur = self.parent(cur)
        return None


class Rule:
    """Base class: subclasses set ``name`` and implement ``check``."""

    name = "rule"

    def check(self, ctx: FileContext) -> List[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, ctx: FileContext, line: int, message: str) -> Finding:
        return Finding(
            rule=self.name,
            path=ctx.path,
            line=line,
            message=message,
            waived=ctx.is_waived(self.name, line),
        )


# -- dotted-name resolution shared by rules ---------------------------------


def dotted_name(node: ast.AST) -> Optional[str]:
    """'jax.jit' for Attribute(jax, jit); 'jit' for Name(jit); None else."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    return dotted_name(call.func)


def iter_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        else:
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [
                    d for d in dirnames
                    if d not in ("__pycache__", ".git", ".venv")
                ]
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        yield os.path.join(dirpath, fn)


def run_files(
    paths: Sequence[str],
    rules: Sequence[Rule],
    config: Optional[Config] = None,
) -> List[Finding]:
    """Run every rule over every file; returns all findings (waived ones
    carry ``waived=True``)."""
    from tools.reprolint import config as config_mod

    cfg = config if config is not None else config_mod.load()
    findings: List[Finding] = []
    for path in iter_files(paths):
        with open(path, encoding="utf-8") as f:
            source = f.read()
        try:
            ctx = FileContext(path, source, cfg)
        except SyntaxError as e:
            findings.append(Finding(
                rule="parse", path=path, line=e.lineno or 0,
                message=f"syntax error: {e.msg}",
            ))
            continue
        for rule in rules:
            findings.extend(rule.check(ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
